"""Training substrate tests: optimizer, loss, data, checkpoint, e2e loss drop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.train import (
    OptConfig,
    checkpoint,
    compression,
    data,
    init_state,
    make_train_step,
    optimizer,
    quorum_grad,
)
from repro.train.losses import chunked_xent


def small_cfg():
    return get_smoke_config("stablelm_12b").replace(dtype="float32")


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        ocfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = optimizer.init(ocfg, params)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, state, _ = optimizer.update(ocfg, params, grads, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1.0

    def test_schedule_shape(self):
        ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        lrs = [float(optimizer.schedule(ocfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[2] > lrs[3] > lrs[4]
        assert lrs[4] == pytest.approx(0.1, abs=0.01)

    def test_grad_clipping(self):
        ocfg = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        state = optimizer.init(ocfg, params)
        _, _, metrics = optimizer.update(
            ocfg, params, {"w": jnp.full(4, 100.0)}, state
        )
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_int8_state_roundtrip_convergence(self):
        """8-bit m/v still minimizes a quadratic (beyond-paper feature)."""
        ocfg = OptConfig(
            lr=0.1, warmup_steps=0, weight_decay=0.0, int8_state=True, int8_block=64
        )
        params = {"w": jnp.linspace(-4, 4, 128)}
        state = optimizer.init(ocfg, params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = optimizer.update(ocfg, params, grads, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


class TestChunkedLoss:
    def test_matches_unchunked(self):
        cfg = small_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
        targets = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
        hidden, _ = model.hidden_states(params, tokens, remat=False)
        loss_c, _ = chunked_xent(cfg, params, hidden, targets, n_chunks=8)
        logits = model.logits(params, hidden)
        logp = jax.nn.log_softmax(logits, axis=-1)
        want = -jnp.mean(jnp.take_along_axis(logp, targets[..., None], -1))
        np.testing.assert_allclose(float(loss_c), float(want), rtol=1e-5)

    def test_mask(self):
        cfg = small_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
        hidden, _ = model.hidden_states(params, tokens, remat=False)
        targets = tokens
        mask = jnp.zeros((2, 32)).at[:, :16].set(1.0)
        full, m1 = chunked_xent(cfg, params, hidden, targets)
        half, m2 = chunked_xent(cfg, params, hidden, targets, mask=mask)
        assert float(m2["tokens"]) == 32.0
        assert float(m1["tokens"]) == 64.0


class TestData:
    def test_deterministic_and_resumable(self):
        cfg = data.DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
        p1, p2 = data.TokenPipeline(cfg), data.TokenPipeline(cfg)
        b1 = p1.batch_at(7)
        b2 = p2.batch_at(7)  # fresh pipeline "resumed" at step 7
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_sharding_invariance(self):
        """Global batch independent of shard count (elastic invariant)."""
        cfg = data.DataConfig(vocab=100, seq_len=16, global_batch=8, seed=0)
        p = data.TokenPipeline(cfg)
        whole = p.batch_at(3)["tokens"]
        parts = [p.batch_at(3, shard=s, num_shards=4)["tokens"] for s in range(4)]
        np.testing.assert_array_equal(whole, np.concatenate(parts, axis=0))

    def test_targets_shifted(self):
        cfg = data.DataConfig(vocab=100, seq_len=16, global_batch=2, seed=0)
        b = data.TokenPipeline(cfg).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
        }
        man = checkpoint.save(str(tmp_path), 5, tree, n_shards=2, meta={"arch": "t"})
        like = jax.tree.map(jnp.zeros_like, tree)
        out = checkpoint.restore(str(tmp_path), man, like)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10, dtype=np.float32))
        assert out["b"]["c"].dtype == jnp.bfloat16
        assert checkpoint.latest_manifest(str(tmp_path))["step"] == 5

    def test_corruption_detected(self, tmp_path):
        tree = {"a": jnp.arange(4.0)}
        man = checkpoint.save(str(tmp_path), 1, tree)
        path = tmp_path / man["files"]["0"]["path"]
        path.write_bytes(path.read_bytes()[:-7] + b"garbage")
        with pytest.raises(IOError):
            checkpoint.restore(str(tmp_path), man, tree)


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = compression.compress(g)
        back = compression.decompress(q, s, g.shape)
        assert float(jnp.max(jnp.abs(back - g))) < float(jnp.max(jnp.abs(g))) / 100

    def test_error_feedback_converges(self):
        """EF-compressed gradient descent still minimizes a quadratic."""
        w = jnp.array([4.0, -2.0, 1.0, -0.5] * 32)
        res = {"w": jnp.zeros_like(w)}
        for _ in range(200):
            grads = {"w": 2 * w}
            comp, res = compression.ef_compress_tree(grads, res)
            g_hat = compression.decompress_tree(comp, grads)["w"]
            w = w - 0.05 * g_hat
        assert float(jnp.max(jnp.abs(w))) < 0.1

    def test_ef_tree_roundtrip(self):
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (100,))}
        res = compression.zero_residuals(grads)
        comp, new_res = compression.ef_compress_tree(grads, res)
        approx = compression.decompress_tree(comp, grads)
        err = float(jnp.max(jnp.abs(approx["w"] - grads["w"])))
        assert err < 0.05
        # residual equals the quantization error
        np.testing.assert_allclose(
            np.asarray(new_res["w"]), np.asarray(grads["w"] - approx["w"]), atol=1e-6
        )


class TestQuorumGrad:
    def test_masked_mean_unbiased(self):
        g = {"w": jnp.stack([jnp.full(3, 1.0), jnp.full(3, 2.0), jnp.full(3, 99.0)])}
        mask = jnp.array([1.0, 1.0, 0.0])  # third pod straggles
        out = quorum_grad.quorum_mean(g, mask)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.5)

    def test_quorum_threshold(self):
        assert bool(quorum_grad.quorum_ok(jnp.array([1, 1, 1, 0.0]), f=1))
        assert not bool(quorum_grad.quorum_ok(jnp.array([1, 1, 0, 0.0]), f=1))


class TestEndToEnd:
    def test_loss_decreases(self):
        cfg = small_cfg()
        dcfg = data.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
        pipe = data.TokenPipeline(dcfg)
        ocfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60, weight_decay=0.01)
        state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, ocfg))
        losses = []
        for i in range(30):
            state, metrics = step(state, pipe.jax_batch_at(i))
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
